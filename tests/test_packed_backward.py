"""Packed triangular BACKWARD == per-document sequential backward.

Property-tests the training tentpole end to end:

  * kernel level — jax.grad through ops.packed_prefill_attention matches
    the numpy-f64 gradient oracle on BOTH the scan and Pallas custom-VJP
    paths, for mixed ltm/band/prefix members (no fallback: the Pallas
    grad jaxpr contains the packed fwd + dq + dkv pallas_calls and no
    scan loop);
  * property (hypothesis, shimmed offline) — packed-batch grads equal
    per-document sequential grads for random member mixes;
  * train level — a packed ragged-document train step produces the SAME
    loss and parameter gradients as the pad-to-max padded batch over the
    identical documents, and make_train_step(packed=...) steps cleanly;
  * data level — pack_documents (first-fit decreasing) places every doc
    exactly once within capacity, and PackedDocsLM emits consistent
    tokens/labels/mask/positions for packed and padded layouts.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings
from hypothesis import strategies as st

import oracles as O
from repro.configs import registry as REG
from repro.kernels.tri_attn import ops as OPS
from repro.models import model as MD
from repro.train import data as DATA
from repro.train import optimizer as OPT
from repro.train import train_step as TS

# mixed member zoo: ltm + band + prefix in one packed launch
LENS = (32, 8, 16)
WINDOWS = (None, None, 8)
PREFIXES = (0, 4, 0)
BLK = 8


def _vjp_grads(impl, q, k, v, do, psched):
    f = lambda q_, k_, v_: OPS.packed_prefill_attention(q_, k_, v_, psched,
                                                        impl=impl)
    _, vjp = jax.vjp(f, q, k, v)
    return vjp(do)


@pytest.mark.parametrize("impl", ["scan", "pallas"])
def test_packed_grad_matches_f64_oracle(impl):
    s = sum(LENS)
    q, k, v = O.rand_qkv(0, 1, 4, 2, s, 16)
    do = jax.random.normal(jax.random.PRNGKey(9), q.shape, jnp.float32)
    psched = OPS.make_packed_sched(LENS, block=BLK, window=list(WINDOWS),
                                   prefix=list(PREFIXES))
    dq, dk, dv = _vjp_grads(impl, q, k, v, do, psched)
    wq, wk, wv = O.packed_attention_grad_oracle(
        q, k, v, do, LENS, windows=WINDOWS, prefixes=PREFIXES)
    O.assert_close(dq, wq, "attn_grad", err_msg=f"dq {impl}")
    O.assert_close(dk, wk, "attn_grad", err_msg=f"dk {impl}")
    O.assert_close(dv, wv, "attn_grad", err_msg=f"dv {impl}")


def test_pallas_grad_runs_packed_bwd_not_fallback():
    """The Pallas path's backward is the packed dq + dk/dv kernels: the
    grad jaxpr carries three pallas_call equations (fwd, dq, dkv) and no
    lax.scan fallback loop."""
    s = sum(LENS)
    q, k, v = O.rand_qkv(1, 1, 2, 1, s, 8)
    psched = OPS.make_packed_sched(LENS, block=BLK)
    jaxpr = str(jax.make_jaxpr(jax.grad(
        lambda q_: jnp.sum(OPS.packed_prefill_attention(
            q_, k, v, psched, impl="pallas"))))(q))
    assert jaxpr.count("pallas_call") == 3, jaxpr.count("pallas_call")
    assert "scan[" not in jaxpr


@given(st.data())
@settings(max_examples=6, deadline=None)
def test_property_packed_grads_equal_per_document(data):
    """Random member mixes (ltm/band/prefix, random tile counts): the ONE
    packed backward equals the per-document sequential backward exactly
    (same schedules, same op order per member)."""
    r = data.draw(st.integers(min_value=1, max_value=4))
    blk = 4 * data.draw(st.integers(min_value=1, max_value=2))
    lens, wins, pres = [], [], []
    for _ in range(r):
        n = data.draw(st.integers(min_value=1, max_value=4))
        kind = data.draw(st.sampled_from(["ltm", "band", "prefix"]))
        lens.append(n * blk)
        wins.append(data.draw(st.integers(1, n * blk))
                    if kind == "band" else None)
        pres.append(data.draw(st.integers(1, n * blk))
                    if kind == "prefix" and n > 1 else 0)
    s = sum(lens)
    q, k, v = O.rand_qkv(data.draw(st.integers(0, 99)), 1, 2, 1, s, 8)
    do = jax.random.normal(jax.random.PRNGKey(3), q.shape, jnp.float32)
    psched = OPS.make_packed_sched(lens, block=blk, window=wins,
                                   prefix=pres)
    got = _vjp_grads("scan", q, k, v, do, psched)

    base, want = 0, [[], [], []]
    for s_r, w, p in zip(lens, wins, pres):
        seg = slice(base, base + s_r)
        f = lambda q_, k_, v_: OPS.triangular_attention(
            q_, k_, v_, window=w, prefix=p, impl="scan", block_q=blk,
            block_k=blk)
        _, vjp = jax.vjp(f, q[:, :, seg], k[:, :, seg], v[:, :, seg])
        for acc, g in zip(want, vjp(do[:, :, seg])):
            acc.append(g)
        base += s_r
    for g, w_parts, nm in zip(got, want, "qkv"):
        O.assert_close(g, jnp.concatenate(w_parts, axis=2),
                       "attn_bitwise_pair",
                       err_msg=f"d{nm} {lens} {wins} {pres}")


# ---------------------------------------------------------------------------
# train level: packed ragged batch == pad-to-max batch, same documents
# ---------------------------------------------------------------------------


def test_train_step_packed_equals_padded():
    """Same documents, two layouts: the packed ragged row and the
    pad-to-max batch produce identical loss and parameter grads (the mask
    restricts both means to the same real-token set; packed attention is
    per-doc causal-isolated). Then make_train_step(packed=...) takes a
    full optimizer step on the packed batch."""
    cfg = REG.smoke_config("yi-9b")
    params = MD.init_params(jax.random.key(0), cfg)
    docs = DATA.PackedDocsLM(cfg, (13, 3, 7), block=4, seed=1)
    psched = OPS.make_packed_sched(docs.member_lens, block=4,
                                   window=cfg.sliding_window)
    batch, padded = docs.batch(0), docs.padded_batch(0)

    def packed_loss(p):
        return MD.loss_fn(p, cfg, batch, packed=psched, aux_weight=0.0,
                          block=4)[0]

    def padded_loss(p):
        return MD.loss_fn(p, cfg, padded, aux_weight=0.0, block=4)[0]

    (lp, gp) = jax.value_and_grad(packed_loss)(params)
    (ld, gd) = jax.value_and_grad(padded_loss)(params)
    np.testing.assert_allclose(float(lp), float(ld), rtol=1e-6)
    err = jax.tree.reduce(max, jax.tree.map(
        lambda a, b: float(jnp.max(jnp.abs(a - b))), gp, gd))
    assert err < 2e-6, err

    opt = OPT.OptConfig()
    state = TS.init_state(jax.random.key(0), cfg, opt)
    step = TS.make_train_step(cfg, opt, packed=psched, aux_weight=0.0,
                              block=4)
    state2, metrics = step(state, batch)
    assert np.isfinite(float(metrics["loss"]))
    assert int(state2.step) == 1


# ---------------------------------------------------------------------------
# data level: bin packing + batch construction
# ---------------------------------------------------------------------------


@given(st.data())
@settings(max_examples=10, deadline=None)
def test_property_pack_documents_ffd(data):
    block = 4
    cap = block * data.draw(st.integers(min_value=2, max_value=8))
    n = data.draw(st.integers(min_value=1, max_value=12))
    lens = [data.draw(st.integers(min_value=1, max_value=cap))
            for _ in range(n)]
    bins = DATA.pack_documents(lens, cap, block=block)
    placed = sorted(i for b in bins for i in b)
    assert placed == list(range(n))  # every doc exactly once
    pad = lambda s: -(-s // block) * block
    for b in bins:
        assert sum(pad(lens[i]) for i in b) <= cap
        assert [pad(lens[i]) for i in b] == \
            sorted([pad(lens[i]) for i in b], reverse=True)


def test_packed_docs_batch_layout():
    cfg = REG.smoke_config("yi-9b")
    docs = DATA.PackedDocsLM(cfg, (5, 2, 9), block=4, seed=3)
    assert docs.member_lens == (8, 4, 12)
    b = docs.batch(2)
    assert b["tokens"].shape == (1, 24)
    # positions restart per document and run through the pad tail
    want_pos = np.concatenate([np.arange(8), np.arange(4), np.arange(12)])
    np.testing.assert_array_equal(np.asarray(b["positions"][0]), want_pos)
    # mask covers exactly the raw doc lengths, at each member's start
    mask = np.asarray(b["mask"][0])
    assert mask.sum() == 5 + 2 + 9
    np.testing.assert_array_equal(mask[:5], 1)
    np.testing.assert_array_equal(mask[5:8], 0)
    # same real tokens appear in the padded layout, row-aligned
    p = docs.padded_batch(2)
    assert p["tokens"].shape == (3, 12)
    np.testing.assert_array_equal(np.asarray(p["tokens"][0, :5]),
                                  np.asarray(b["tokens"][0, :5]))
    np.testing.assert_array_equal(np.asarray(p["labels"][2, :9]),
                                  np.asarray(b["labels"][0, 12:21]))
    # deterministic per (seed, step); different steps differ
    b2 = docs.batch(2)
    np.testing.assert_array_equal(np.asarray(b["tokens"]),
                                  np.asarray(b2["tokens"]))
    assert not np.array_equal(np.asarray(docs.batch(3)["tokens"]),
                              np.asarray(b["tokens"]))
