"""Fused WKV6 kernel vs oracle: shape/dtype sweeps (interpret mode), state
chaining, and equivalence with the model's chunked formulation."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.wkv_scan import ops as O
from repro.kernels.wkv_scan import ref as R


def _inputs(key, b, l, h, hd, dtype=jnp.float32):
    ks = jax.random.split(key, 5)
    r = jax.random.normal(ks[0], (b, l, h, hd), dtype) * 0.5
    k = jax.random.normal(ks[1], (b, l, h, hd), dtype) * 0.5
    v = jax.random.normal(ks[2], (b, l, h, hd), dtype) * 0.5
    lw = -jnp.exp(jax.random.normal(ks[3], (b, l, h, hd), jnp.float32)
                  * 0.5).astype(dtype)  # log-decay < 0
    u = jax.random.normal(ks[4], (h, hd), jnp.float32) * 0.5
    return r, k, v, lw, u


@pytest.mark.parametrize("b,l,h,hd", [
    (1, 8, 1, 8),
    (2, 32, 4, 16),
    (2, 64, 2, 64),
    (1, 128, 8, 32),
])
def test_allclose_vs_ref_shapes(b, l, h, hd):
    r, k, v, lw, u = _inputs(jax.random.key(0), b, l, h, hd)
    o_k, s_k = O.wkv(r, k, v, lw, u, impl="pallas", block_l=min(16, l))
    o_r, s_r = R.wkv_ref(r, k, v, lw, u)
    np.testing.assert_allclose(np.asarray(o_k), np.asarray(o_r),
                               rtol=1e-4, atol=1e-4)
    np.testing.assert_allclose(np.asarray(s_k), np.asarray(s_r),
                               rtol=1e-4, atol=1e-4)


def test_bfloat16():
    r, k, v, lw, u = _inputs(jax.random.key(1), 2, 32, 2, 16, jnp.bfloat16)
    o_k, _ = O.wkv(r, k, v, lw, u, impl="pallas", block_l=16)
    o_r, _ = R.wkv_ref(r, k, v, lw, u)
    assert o_k.dtype == jnp.bfloat16
    np.testing.assert_allclose(np.asarray(o_k, np.float32),
                               np.asarray(o_r.astype(jnp.float32)),
                               rtol=5e-2, atol=5e-2)


def test_state_chaining():
    r, k, v, lw, u = _inputs(jax.random.key(2), 1, 64, 2, 16)
    o_full, s_full = O.wkv(r, k, v, lw, u, impl="pallas", block_l=16)
    o1, s1 = O.wkv(r[:, :32], k[:, :32], v[:, :32], lw[:, :32], u,
                   impl="pallas", block_l=16)
    o2, s2 = O.wkv(r[:, 32:], k[:, 32:], v[:, 32:], lw[:, 32:], u, s0=s1,
                   impl="pallas", block_l=16)
    np.testing.assert_allclose(np.asarray(jnp.concatenate([o1, o2], 1)),
                               np.asarray(o_full), rtol=1e-4, atol=1e-4)
    np.testing.assert_allclose(np.asarray(s2), np.asarray(s_full),
                               rtol=1e-4, atol=1e-4)


def test_matches_model_chunked_wkv():
    """kernel == models/rwkv6._wkv_chunk (the exp-argument formulation)."""
    from repro.models.rwkv6 import _wkv_chunk
    b, l, h, hd = 2, 32, 2, 16
    r, k, v, lw, u = _inputs(jax.random.key(3), b, l, h, hd)
    s0 = jnp.zeros((b, h, hd, hd), jnp.float32)
    o_chunk, s_chunk = _wkv_chunk(s0, r, k, v, lw, u)
    o_k, s_k = O.wkv(r, k, v, lw, u, impl="pallas", block_l=16)
    np.testing.assert_allclose(np.asarray(o_k), np.asarray(o_chunk),
                               rtol=1e-4, atol=1e-4)
    np.testing.assert_allclose(np.asarray(s_k), np.asarray(s_chunk),
                               rtol=1e-4, atol=1e-4)
