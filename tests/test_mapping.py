"""Property + unit tests for the paper's mapping functions (core/mapping.py).

The paper's central correctness claim is that g(lambda) is a bijection from
[0, T(n)) onto the lower triangle {(i,j): j <= i < n}. We verify it exactly,
host-side and traced, far beyond the paper's N < 30,720 exactness envelope.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings
from hypothesis import strategies as st

from repro.core import mapping as M
from repro.core import schedule as S


# ---------------------------------------------------------------------------
# LTM g(lambda)
# ---------------------------------------------------------------------------


@given(st.integers(min_value=0, max_value=2**52))
def test_ltm_host_bijection_roundtrip(lam):
    i, j = M.ltm_map(lam)
    assert 0 <= j <= i
    assert M.ltm_inverse(i, j) == lam


# Traced exactness envelope: int32 needs 8*lam+1 and r*r to stay < 2^31,
# i.e. lam <= T(16383) ~ 1.34e8 (n <= 16383 tiles/side; seq ~2M tokens at
# rho=128). ~100x beyond the paper's N < 30,720 envelope.
@given(st.integers(min_value=0, max_value=M.tri(16383) - 1))
@settings(max_examples=200)
def test_ltm_traced_matches_host(lam):
    i_h, j_h = M.ltm_map(lam)
    i_t, j_t = M.ltm_map(jnp.asarray(lam, jnp.int32))
    assert (int(i_t), int(j_t)) == (i_h, j_h)


def test_ltm_enumerates_lower_triangle_exactly():
    n = 53
    seen = {M.ltm_map(l) for l in range(M.tri(n))}
    expect = {(i, j) for i in range(n) for j in range(i + 1)}
    assert seen == expect


def test_ltm_row_major_contiguity():
    # The property the flash-attention kernel relies on: for fixed i the
    # lambdas are contiguous and j ascends 0..i.
    for i in range(40):
        lams = [M.ltm_inverse(i, j) for j in range(i + 1)]
        assert lams == list(range(lams[0], lams[0] + i + 1))


def test_ltm_nodiag():
    n = 30
    seen = {M.ltm_map_nodiag(l) for l in range(M.tri(n - 1))}
    expect = {(i, j) for i in range(1, n) for j in range(i)}
    assert seen == expect


@given(st.integers(min_value=0, max_value=M.tri(30720 // 16) - 1))
@settings(max_examples=300)
def test_ltm_float_r_exact_in_paper_envelope(lam):
    """LTM-R (rsqrt + eps) is exact within the paper's N<30,720, rho=16."""
    i_r, j_r = M.ltm_map_float_r(jnp.asarray(lam))
    assert (int(i_r), int(j_r)) == M.ltm_map(lam)


def test_isqrt_traced_exact_near_squares():
    xs = []
    for r in [0, 1, 2, 5, 1000, 20000, 32767]:
        for d in (-1, 0, 1):
            v = r * r + d
            if v >= 0:
                xs.append(v)
    xs = jnp.asarray(xs, jnp.int32)
    got = jax.jit(M.isqrt)(xs)
    import math

    assert [int(g) for g in got] == [math.isqrt(int(x)) for x in xs]


# ---------------------------------------------------------------------------
# Competitors: UTM, RB, REC, BB
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("n", [2, 3, 7, 16, 33])
def test_utm_covers_strict_upper_triangle(n):
    seen = {M.utm_map(k, n) for k in range(M.tri(n - 1))}
    expect = {(a, b) for a in range(n) for b in range(a + 1, n)}
    assert seen == expect


@given(st.integers(min_value=2, max_value=200), st.data())
@settings(max_examples=100)
def test_utm_roundtrip(n, data):
    k = data.draw(st.integers(min_value=0, max_value=M.tri(n - 1) - 1))
    a, b = M.utm_map(k, n)
    assert M.utm_inverse(a, b, n) == k


@pytest.mark.parametrize("n", [2, 4, 5, 8, 9, 16, 31])
def test_rb_covers_lower_triangle(n):
    sched = S.RBSchedule(n=n)
    seen = set()
    for lam in range(sched.num_blocks):
        if sched.host_active(lam):
            ij = sched.host_map(lam)
            assert ij not in seen, f"duplicate {ij}"
            seen.add(ij)
    expect = {(i, j) for i in range(n) for j in range(i + 1)}
    assert seen == expect


@pytest.mark.parametrize("n,m", [(4, 1), (8, 2), (16, 4), (32, 4)])
def test_rec_covers_lower_triangle(n, m):
    sched = S.RECSchedule(n=n, m=m)
    seen = sched.enumerate_host()
    assert len(seen) == len(set(seen)) == M.tri(n)
    assert set(seen) == {(i, j) for i in range(n) for j in range(i + 1)}


def test_rec_rejects_bad_shapes():
    with pytest.raises(AssertionError):
        M.rec_schedule(12, 5)


# ---------------------------------------------------------------------------
# Band & prefix schedules (beyond-paper)
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("n,w", [(8, 1), (8, 3), (16, 4), (16, 16), (33, 7)])
def test_band_covers_band(n, w):
    sched = S.BandSchedule(n=n, w=w)
    seen = [sched.host_map(l) for l in range(sched.num_blocks)]
    assert len(seen) == len(set(seen))
    expect = {(i, j) for i in range(n) for j in range(max(0, i - w + 1), i + 1)}
    assert set(seen) == expect


@given(
    st.integers(min_value=1, max_value=300),
    st.integers(min_value=1, max_value=300),
    st.data(),
)
@settings(max_examples=150)
def test_band_roundtrip(n, w, data):
    w = min(w, n)
    lam = data.draw(st.integers(min_value=0, max_value=M.band_blocks(n, w) - 1))
    i, j = M.band_map(lam, w)
    assert max(0, i - w + 1) <= j <= i
    assert M.band_inverse(i, j, w) == lam


def test_band_traced_matches_host():
    n, w = 50, 9
    lams = np.arange(M.band_blocks(n, w))
    it, jt = jax.jit(lambda l: M.band_map(l, w))(jnp.asarray(lams))
    host = [M.band_map(int(l), w) for l in lams]
    np.testing.assert_array_equal(np.asarray(it), [h[0] for h in host])
    np.testing.assert_array_equal(np.asarray(jt), [h[1] for h in host])


@pytest.mark.parametrize("n,p", [(8, 1), (8, 3), (16, 5), (9, 9)])
def test_prefix_covers_prefix_causal(n, p):
    sched = S.PrefixSchedule(n=n, p=p)
    seen = [sched.host_map(l) for l in range(sched.num_blocks)]
    assert len(seen) == len(set(seen))
    expect = {(i, j) for i in range(n) for j in range(n) if j <= i or j < p}
    assert set(seen) == expect


# ---------------------------------------------------------------------------
# Waste accounting (paper Fig. 3 right / §II)
# ---------------------------------------------------------------------------


def test_strategy_stats_match_paper_claims():
    from repro.core import analysis as A

    n = 64
    stats = A.strategy_stats(n, band_w=8, rec_m=1)
    assert stats["bb"].wasted == M.tri(n - 1) == n * (n - 1) // 2  # O(n^2)
    assert stats["ltm"].wasted == 0  # block-level: only intra-diag masking
    assert stats["ltm"].launched == M.tri(n)
    assert abs(stats["bb"].block_ratio_vs_bb - 1.0) < 1e-9
    # paper: I -> 2 for large n at k=1
    assert 1.9 < stats["ltm"].block_ratio_vs_bb < 2.0
    assert stats["rb"].launched <= M.tri(n) + n + 1  # O(n) overhead
    assert stats["rec"].launched == M.tri(n)


def test_improvement_factor_model():
    from repro.core import analysis as A

    # paper: k in [1.5, 2) -> I in (1, 1.33]; k >= 2 -> no improvement
    assert 1.0 < A.improvement_factor(1000, k_cost=1.74) < 1.33
    assert A.improvement_factor(1000, k_cost=2.1) < 1.0
    assert 1.99 < A.improvement_factor(10000, k_cost=1.0) < 2.0


# ---------------------------------------------------------------------------
# Schedules: traced index_map == host_map for every schedule kind
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("kind,kw", [
    ("ltm", {}),
    ("bb", {}),
    ("band", {"w": 5}),
    ("prefix", {"p": 3}),
    ("utm", {}),
    ("rb", {}),
])
def test_traced_index_map_matches_host(kind, kw):
    n = 17
    sched = S.make_schedule(kind, n, **kw)
    lams = jnp.arange(sched.num_blocks)
    it, jt = jax.jit(jax.vmap(sched.index_map))(lams)
    for l in range(sched.num_blocks):
        assert (int(it[l]), int(jt[l])) == tuple(sched.host_map(l)), (kind, l)


# ---------------------------------------------------------------------------
# Column-major maps (backward-pass enumerations)
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("n", [1, 2, 3, 8, 17, 64])
def test_cm_map_covers_lower_triangle_col_major(n):
    seen = [M.cm_map(l, n) for l in range(M.tri(n))]
    assert len(set(seen)) == M.tri(n)
    # column-major: j non-decreasing, i contiguous within a column
    js = [j for _, j in seen]
    assert js == sorted(js)
    for l, (i, j) in enumerate(seen):
        assert j <= i < n
        assert M.cm_inverse(i, j, n) == l


@given(st.integers(min_value=1, max_value=3000), st.data())
@settings(max_examples=100)
def test_cm_roundtrip(n, data):
    lam = data.draw(st.integers(min_value=0, max_value=M.tri(n) - 1))
    i, j = M.cm_map(lam, n)
    assert M.cm_inverse(i, j, n) == lam


def test_cm_traced_matches_host():
    n = 37
    lams = jnp.arange(M.tri(n))
    it, jt = jax.jit(jax.vmap(lambda l: M.cm_map(l, n)))(lams)
    for l in range(M.tri(n)):
        assert (int(it[l]), int(jt[l])) == M.cm_map(l, n)


@pytest.mark.parametrize("n,w", [(8, 1), (8, 3), (16, 4), (16, 16), (33, 7), (5, 5)])
def test_band_cm_covers_band_col_major(n, w):
    total = M.band_blocks(n, w)
    seen = [M.band_cm_map(l, n, w) for l in range(total)]
    assert len(set(seen)) == total
    js = [j for _, j in seen]
    assert js == sorted(js)  # column-major order
    expect = {(i, j) for i in range(n) for j in range(max(0, i - w + 1), i + 1)}
    assert set(seen) == expect
    # contiguous i within each column
    from itertools import groupby

    idx = 0
    for j, grp in groupby(seen, key=lambda t: t[1]):
        rows = [i for i, _ in grp]
        assert rows == list(range(rows[0], rows[0] + len(rows)))


def test_band_cm_traced_matches_host():
    n, w = 21, 6
    total = M.band_blocks(n, w)
    lams = jnp.arange(total)
    it, jt = jax.jit(jax.vmap(lambda l: M.band_cm_map(l, n, w)))(lams)
    for l in range(total):
        assert (int(it[l]), int(jt[l])) == M.band_cm_map(l, n, w)


@pytest.mark.parametrize("n,p", [(8, 1), (8, 3), (16, 5), (9, 9), (6, 0)])
def test_prefix_cm_covers_domain(n, p):
    total = M.prefix_full_blocks(n, p)
    seen = [M.prefix_cm_map(l, n, p) for l in range(total)]
    assert len(set(seen)) == total
    expect = {(i, j) for i in range(n) for j in range(n) if j <= i or j < p}
    assert set(seen) == expect
    js = [j for _, j in seen]
    assert js == sorted(js)


def test_prefix_cm_traced_matches_host():
    n, p = 13, 4
    total = M.prefix_full_blocks(n, p)
    lams = jnp.arange(total)
    it, jt = jax.jit(jax.vmap(lambda l: M.prefix_cm_map(l, n, p)))(lams)
    for l in range(total):
        assert (int(it[l]), int(jt[l])) == M.prefix_cm_map(l, n, p)
