"""Exhaustive small-n round-trip fuzz for EVERY registered schedule.

One sweep owns the structural claim all kernel families lean on: for each
``make_schedule`` kind and every n in 1..64 (every valid n for REC), the
launch enumeration covers each ACTIVE domain cell exactly once, and where
an inverse exists, map -> inverse is the identity. The traced maps (the
same scalar closed forms the Pallas index_maps run) are evaluated
vectorized, with each kind's whole n-sweep fused into ONE jit call — one
XLA compile per kind instead of ~10 eager op-compiles per (op, n) shape —
and cross-checked against the eager host maps on a Fibonacci subset of n.

Includes ``packed`` with nested mixed members (ltm/band/prefix/row — the
decode-round member among them), so the shared grid machinery is fuzzed
through the same sweep as the per-domain schedules.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import mapping as M
from repro.core import schedule as S
from repro.core.packing import PackedSchedule, _member_inverse

N_MAX = 64
HOST_NS = (1, 2, 3, 5, 8, 13, 21, 34, 55, 64)  # eager-vs-traced subset


def jit_sweep(builders):
    """Evaluate every builder's traced outputs in ONE jit call."""
    return jax.jit(lambda: [b() for b in builders])()


def canon(cols) -> np.ndarray:
    """Stack coordinate vectors into lexsorted (N, rank) rows."""
    a = np.stack([np.asarray(c, np.int64).ravel() for c in cols], axis=1)
    return a[np.lexsort(a.T[::-1])] if len(a) else a


def check_cover(coords, expect: np.ndarray, ctx=""):
    """coords enumerate exactly the expected cells, each exactly once."""
    got = canon(coords)
    if len(got) > 1:  # exactly-once: lexsorted rows are all distinct
        assert (np.diff(got, axis=0) != 0).any(axis=1).all(), ctx
    np.testing.assert_array_equal(got, expect, err_msg=ctx)


def check_host(sched, coords, active_host=None):
    """Eager host_map == the traced enumeration, every lambda."""
    traced = [np.asarray(c) for c in coords]
    for lam in range(sched.num_blocks):
        if active_host is not None and not active_host(lam):
            continue
        assert tuple(int(c[lam]) for c in traced) == tuple(
            sched.host_map(lam)), (sched, lam)


def tril_cells(n):
    return canon(np.tril_indices(n))


def band_cells(n, w):
    i, j = np.tril_indices(n)
    keep = (i - j) < w
    return canon((i[keep], j[keep]))


def prefix_cells(n, p):
    i, j = [a.ravel() for a in np.indices((n, n))]
    keep = (j <= i) | (j < p)
    return canon((i[keep], j[keep]))


def simplex_cells(n):
    i, j, k = [a.ravel() for a in np.indices((n, n, n))]
    keep = (k <= j) & (j <= i)
    return canon((i[keep], j[keep], k[keep]))


def _map_with(sched, extra=None):
    """Builder: traced coords (+ optional extra(coords) pytree)."""
    def build():
        lams = jnp.arange(sched.num_blocks, dtype=jnp.int32)
        coords = sched.index_map(lams)
        return coords, (extra(sched, coords, lams) if extra else None)
    return build


# ---------------------------------------------------------------------------
# per-kind sweeps, n in 1..64
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("kind", ["ltm", "utm"])
def test_triangular_kinds_cover_and_invert(kind):
    scheds = [S.make_schedule(kind, n) for n in range(1, N_MAX + 1)]
    inv = None if kind == "utm" else (
        lambda s, c, lams: M.ltm_inverse(c[0], c[1]))
    results = jit_sweep([_map_with(s, inv) for s in scheds])
    for sched, (coords, extra) in zip(scheds, results):
        check_cover(coords, tril_cells(sched.n), str(sched))
        if extra is not None:  # map -> inverse is the identity
            np.testing.assert_array_equal(np.asarray(extra),
                                          np.arange(sched.num_blocks))
        if sched.n in HOST_NS:
            check_host(sched, coords)


@pytest.mark.parametrize("kind", ["bb"])
def test_bb_covers_square_active_is_triangle(kind):
    scheds = [S.make_schedule(kind, n) for n in range(1, N_MAX + 1)]
    act = lambda s, c, lams: s.active(lams)
    results = jit_sweep([_map_with(s, act) for s in scheds])
    for sched, ((i, j), active) in zip(scheds, results):
        n = sched.n
        assert sched.num_blocks == n * n
        # full launch covers the n*n square once; ACTIVE cells are the tri
        check_cover((i, j), canon([a.ravel() for a in np.indices((n, n))]))
        keep = np.asarray(active, bool)
        check_cover((np.asarray(i)[keep], np.asarray(j)[keep]),
                    tril_cells(n), str(sched))
        assert keep.sum() == sched.domain_blocks
        if n in HOST_NS:
            check_host(sched, (i, j))


def test_band_cover_and_invert():
    cases = [(n, w) for n in range(1, N_MAX + 1)
             for w in sorted({1, 2, (n + 1) // 2, n, n + 3}) if w >= 1]
    scheds = [S.make_schedule("band", n, w=w) for n, w in cases]
    results = jit_sweep([_map_with(s) for s in scheds])
    for (n, w), sched, (coords, _) in zip(cases, scheds, results):
        w_eff = min(w, n)
        check_cover(coords, band_cells(n, w_eff), str(sched))
        for lam in range(0, sched.num_blocks,
                         max(1, sched.num_blocks // 17)):
            i, j = sched.host_map(lam)
            assert M.band_inverse(i, j, w_eff) == lam
        if n in HOST_NS and w == 2:
            check_host(sched, coords)


def test_prefix_cover_and_invert():
    cases = [(n, p) for n in range(1, N_MAX + 1)
             for p in sorted({0, 1, (n + 1) // 2, n})]
    scheds = [S.make_schedule("prefix", n, p=p) for n, p in cases]
    results = jit_sweep([_map_with(s) for s in scheds])
    for (n, p), sched, (coords, _) in zip(cases, scheds, results):
        check_cover(coords, prefix_cells(n, min(p, n)), str(sched))
        for lam in range(0, sched.num_blocks,
                         max(1, sched.num_blocks // 17)):
            i, j = sched.host_map(lam)
            assert _member_inverse(sched, i, j) == lam
        if n in HOST_NS and p == (n + 1) // 2:
            check_host(sched, coords)


def test_row_cover_and_invert():
    scheds = [S.make_schedule("row", n) for n in range(1, N_MAX + 1)]
    results = jit_sweep([_map_with(s) for s in scheds])
    for sched, (coords, _) in zip(scheds, results):
        n = sched.n
        check_cover(coords, canon([a.ravel() for a in np.indices((1, n))]))
        for lam in range(n):
            assert sched.host_map(lam) == (0, lam)
            assert _member_inverse(sched, 0, lam) == lam
        check_host(sched, coords)


def test_rb_active_covers_triangle():
    scheds = [S.make_schedule("rb", n) for n in range(1, N_MAX + 1)]
    act = lambda s, c, lams: s.active(lams)
    results = jit_sweep([_map_with(s, act) for s in scheds])
    for sched, ((i, j), active) in zip(scheds, results):
        n = sched.n
        h, w = sched.grid_shape
        assert sched.num_blocks == h * w >= M.tri(n)
        keep = np.asarray(active, bool)
        check_cover((np.asarray(i)[keep], np.asarray(j)[keep]),
                    tril_cells(n), str(sched))
        if n in HOST_NS:
            check_host(sched, (i, j), active_host=sched.host_active)


@pytest.mark.parametrize("kind", ["tet"])
def test_tet_cover_and_invert(kind):
    scheds = [S.make_schedule(kind, n) for n in range(1, N_MAX + 1)]
    inv = lambda s, c, lams: M.tet_inverse(*c)
    results = jit_sweep([_map_with(s, inv) for s in scheds])
    for sched, (coords, extra) in zip(scheds, results):
        check_cover(coords, simplex_cells(sched.n), str(sched))
        np.testing.assert_array_equal(np.asarray(extra),
                                      np.arange(sched.num_blocks))
        if sched.n in HOST_NS[:6]:  # host tet_map loops; cap the cost
            check_host(sched, coords)


@pytest.mark.parametrize("kind", ["bb3"])
def test_bb3_covers_cube_active_is_simplex(kind):
    scheds = [S.make_schedule(kind, n) for n in range(1, N_MAX + 1)]
    act = lambda s, c, lams: s.active(lams)
    results = jit_sweep([_map_with(s, act) for s in scheds])
    for sched, (coords, active) in zip(scheds, results):
        n = sched.n
        assert sched.num_blocks == n ** 3
        keep = np.asarray(active, bool)
        check_cover(tuple(np.asarray(c)[keep] for c in coords),
                    simplex_cells(n), str(sched))
        assert keep.sum() == sched.domain_blocks
        if n in HOST_NS[:6]:
            check_host(sched, coords)


def test_registry_aliases_resolve_to_same_schedule():
    """Aliases share the class, so the canonical-name sweeps above cover
    them; pin the resolution here instead of re-running 64-n sweeps."""
    for a, b in (("ltm", "triangular"), ("bb", "dense"),
                 ("tet", "tetrahedral"), ("bb3", "dense3d")):
        assert type(S.make_schedule(a, 5)) is type(S.make_schedule(b, 5))


def test_rec_enumerates_triangle_exactly():
    for m in (1, 2, 3, 5):
        k = 0
        while m << k <= N_MAX:
            n = m << k
            sched = S.make_schedule("rec", n, m=m)
            cells = sched.enumerate_host()
            assert len(cells) == M.tri(n) == sched.domain_blocks
            got = canon(tuple(np.array([c[d] for c in cells])
                              for d in range(2)))
            np.testing.assert_array_equal(got, tril_cells(n))
            k += 1


# ---------------------------------------------------------------------------
# packed, with nested mixed members (incl. the decode-round RowSchedule)
# ---------------------------------------------------------------------------


def _nested_members(n: int):
    """Deterministically split n tile-rows into mixed members cycling the
    four supported kinds (sizes cycle 3,1,4,2 — coprime-ish with the kind
    cycle so every (kind, size) pairing appears across the sweep)."""
    sizes, rem = [], n
    for c in range(64):
        if rem == 0:
            break
        take = min((3, 1, 4, 2)[c % 4], rem)
        sizes.append(take)
        rem -= take
    members = []
    for idx, sz in enumerate(sizes):
        kind = idx % 4
        if kind == 0:
            members.append(S.TriangularSchedule(n=sz))
        elif kind == 1:
            members.append(S.BandSchedule(n=sz, w=1 + idx % 3))
        elif kind == 2:
            members.append(S.PrefixSchedule(n=sz, p=idx % (sz + 1)))
        else:
            members.append(S.RowSchedule(n=sz))
    return tuple(members)


def test_packed_nested_cover_and_roundtrip():
    packs = [S.make_schedule("packed", 0, members=_nested_members(n))
             for n in range(1, N_MAX + 1)]
    results = jit_sweep([_map_with(pk) for pk in packs])
    for n, (pk, (coords, _)) in enumerate(zip(packs, results), start=1):
        assert pk.n == n
        expect = canon(tuple(np.array(v) for v in zip(
            *[(r, i, j) for r, m in enumerate(pk.members)
              for (i, j) in m.enumerate_host()])))
        check_cover(coords, expect, f"packed n={n}")
        # inverse: pack_lambda(host_map(lam)) == lam, exhaustively
        for lam in range(pk.num_blocks):
            assert pk.pack_lambda(*pk.host_map(lam)) == lam
        if n in HOST_NS:
            check_host(pk, coords)


def _mixed_halves(n: int):
    """Deterministically split n tile-rows into a fused step's halves:
    prefill members cycling ltm/band/prefix over the first ceil(n/2) rows,
    the rest as decode kv_tiles. Either half may come out empty (n=1 edge:
    prefill-only), matching real rounds."""
    n_p = (n + 1) // 2
    sizes, rem = [], n_p
    for c in range(64):
        if rem == 0:
            break
        take = min((2, 1, 3)[c % 3], rem)
        sizes.append(take)
        rem -= take
    prefill = []
    for idx, sz in enumerate(sizes):
        kind = idx % 3
        if kind == 0:
            prefill.append(S.TriangularSchedule(n=sz))
        elif kind == 1:
            prefill.append(S.BandSchedule(n=sz, w=1 + idx % 2))
        else:
            prefill.append(S.PrefixSchedule(n=sz, p=idx % (sz + 1)))
    kv, rem = [], n - n_p
    for c in range(64):
        if rem == 0:
            break
        take = min((1, 3, 2)[c % 3], rem)
        kv.append(take)
        rem -= take
    return tuple(prefill), tuple(kv)


def test_mixed_step_cover_and_roundtrip():
    """The fused continuous-batching kind: registry "mixed" packs prefill
    members + decode row members into one grid; cover + pack_lambda
    round-trip fuzzed exactly like "packed" above."""
    cases = [_mixed_halves(n) for n in range(1, N_MAX + 1)]
    packs = [S.make_schedule("mixed", 0, prefill_members=pm, kv_tiles=kv)
             for pm, kv in cases]
    results = jit_sweep([_map_with(pk) for pk in packs])
    for n, ((pm, kv), pk, (coords, _)) in enumerate(
            zip(cases, packs, results), start=1):
        assert pk.n == n
        # member order is the fused ABI: prefill columns first, then one
        # RowSchedule per decode slot
        assert len(pk.members) == len(pm) + len(kv)
        assert all(not isinstance(m, S.RowSchedule)
                   for m in pk.members[:len(pm)])
        assert all(isinstance(m, S.RowSchedule) and m.n == t
                   for m, t in zip(pk.members[len(pm):], kv))
        expect = canon(tuple(np.array(v) for v in zip(
            *[(r, i, j) for r, m in enumerate(pk.members)
              for (i, j) in m.enumerate_host()])))
        check_cover(coords, expect, f"mixed n={n}")
        for lam in range(pk.num_blocks):
            assert pk.pack_lambda(*pk.host_map(lam)) == lam
        if n in HOST_NS:
            check_host(pk, coords)


def test_mixed_step_rejects_row_prefill_and_empty():
    with pytest.raises(ValueError, match="decode half"):
        S.make_schedule("mixed", 0,
                        prefill_members=(S.RowSchedule(n=2),),
                        kv_tiles=(1,))
    with pytest.raises(ValueError, match="at least one member"):
        S.make_schedule("mixed", 0, prefill_members=(), kv_tiles=())
    with pytest.raises(ValueError, match="mixed n must be"):
        S.make_schedule("mixed", 7,
                        prefill_members=(S.TriangularSchedule(n=2),),
                        kv_tiles=(3,))


def test_packed_decode_round_is_row_pack():
    """decode_round(kv_tiles) == packed RowSchedule members: the decode
    grid is the same machinery the prefill pack fuzzes above."""
    rounds = ([1], [3, 1, 5], [2] * 7, list(range(1, 9)))
    packs = [PackedSchedule.decode_round(kv) for kv in rounds]
    results = jit_sweep([_map_with(pk) for pk in packs])
    for kv_tiles, pk, (coords, _) in zip(rounds, packs, results):
        assert [m.n for m in pk.members] == kv_tiles
        assert all(isinstance(m, S.RowSchedule) for m in pk.members)
        assert pk.num_blocks == sum(kv_tiles)
        cells = pk.enumerate_host()
        assert cells == [(r, 0, j) for r, t in enumerate(kv_tiles)
                         for j in range(t)]
        check_host(pk, coords)
