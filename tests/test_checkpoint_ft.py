"""Checkpointing + fault tolerance: atomic saves, elastic restore,
crash-resume bit-exactness, heartbeats/stragglers, preemption, re-planning.
"""

import os
import signal
import threading

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, strategies as st

from repro.configs import registry as REG
from repro.configs.base import ShapeConfig
from repro.launch.compat import make_mesh
from repro.train import checkpoint as CKPT
from repro.train import data as DATA
from repro.train import fault_tolerance as FT
from repro.train import optimizer as OPT
from repro.train import train_step as TS


def _tiny_setup(seed=0):
    cfg = REG.smoke_config("yi-9b")
    opt = OPT.OptConfig(lr=1e-3, warmup_steps=1, total_steps=50)
    state = TS.init_state(jax.random.key(seed), cfg, opt)
    shape = ShapeConfig("t", 32, 4, "train")
    ds = DATA.SyntheticLM(cfg, shape, seed=seed, act_dtype=jnp.float32)
    step = jax.jit(TS.make_train_step(cfg, opt))
    return cfg, state, ds, step


def _assert_state_equal(a, b):
    for x, y in zip(jax.tree.leaves(a), jax.tree.leaves(b)):
        np.testing.assert_array_equal(np.asarray(x), np.asarray(y))


# ---------------------------------------------------------------------------
# Checkpoint basics
# ---------------------------------------------------------------------------


def test_save_restore_roundtrip(tmp_path):
    _, state, _, _ = _tiny_setup()
    CKPT.save(str(tmp_path), state, 7)
    assert CKPT.latest_step(str(tmp_path)) == 7
    target = jax.tree.map(
        lambda x: jax.ShapeDtypeStruct(x.shape, x.dtype), state)
    restored, manifest = CKPT.restore(str(tmp_path), target)
    assert manifest["step"] == 7
    _assert_state_equal(state, restored)


def test_restore_ignores_partial_tmp(tmp_path):
    _, state, _, _ = _tiny_setup()
    CKPT.save(str(tmp_path), state, 5)
    # simulate a crash mid-save: stale .tmp directory beside the good one
    os.makedirs(tmp_path / "step_00000009.tmp")
    assert CKPT.latest_step(str(tmp_path)) == 5


def test_manager_gc_keeps_last_k(tmp_path):
    _, state, _, _ = _tiny_setup()
    mgr = CKPT.CheckpointManager(str(tmp_path), every=1, keep=2)
    for s in (1, 2, 3, 4):
        mgr.save_sync(state, s)
    steps = sorted(d for d in os.listdir(tmp_path) if d.startswith("step_"))
    assert steps == ["step_00000003", "step_00000004"]


def test_manager_async(tmp_path):
    _, state, _, _ = _tiny_setup()
    mgr = CKPT.CheckpointManager(str(tmp_path), every=1, keep=3)
    mgr.save_async(state, 11)
    mgr.wait()
    assert CKPT.latest_step(str(tmp_path)) == 11


def test_elastic_restore_new_sharding(tmp_path):
    """Restore onto a (trivially different) sharding — the elastic path."""
    _, state, _, _ = _tiny_setup()
    CKPT.save(str(tmp_path), state, 1)
    mesh = make_mesh((1,), ("data",))
    from repro.parallel import sharding as SH
    target = jax.tree.map(
        lambda x: jax.ShapeDtypeStruct(x.shape, x.dtype), state)
    sh = TS.TrainState(
        params=SH.param_shardings(mesh, state.params),
        opt_state=SH.param_shardings(mesh, state.opt_state),
        step=SH.scalar_sharding(mesh), err_state=None)
    restored, _ = CKPT.restore(str(tmp_path), target, shardings=sh)
    _assert_state_equal(state, restored)


# ---------------------------------------------------------------------------
# Crash-resume bit-exactness (THE fault-tolerance invariant)
# ---------------------------------------------------------------------------


def test_crash_resume_bitexact(tmp_path):
    cfg, state0, ds, step = _tiny_setup()
    mgr = CKPT.CheckpointManager(str(tmp_path), every=3, keep=5)

    def step_fn(state, batch):
        return step(state, batch)

    # uninterrupted run to step 8
    ref_state, _ = FT.run_training(
        jax.tree.map(lambda x: x, state0), step_fn, ds.batch, 8)

    # interrupted run: dies at step 5, restores from the step-3 checkpoint
    with pytest.raises(FT.SimulatedFailure):
        FT.run_training(jax.tree.map(lambda x: x, state0), step_fn,
                        ds.batch, 8, manager=mgr, fail_at=5)
    target = jax.tree.map(
        lambda x: jax.ShapeDtypeStruct(x.shape, x.dtype), state0)
    resumed, _ = CKPT.restore(str(tmp_path), target)
    assert int(resumed.step) == 3
    final, _ = FT.run_training(resumed, step_fn, ds.batch, 8, manager=mgr)
    assert int(final.step) == 8
    _assert_state_equal(ref_state.params, final.params)


# ---------------------------------------------------------------------------
# Heartbeats / stragglers / preemption / re-planning
# ---------------------------------------------------------------------------


def test_heartbeat_failure_detection():
    mon = FT.HeartbeatMonitor([0, 1, 2], timeout_s=10.0)
    mon.beat(0, 1, now=0.0)
    mon.beat(1, 1, now=0.0)
    mon.beat(2, 1, now=0.0)
    mon.beat(0, 2, now=8.0)
    mon.beat(1, 2, now=8.0)
    assert mon.failed(now=15.0) == {2}


def test_straggler_detection():
    mon = FT.HeartbeatMonitor([0, 1, 2, 3], straggler_factor=1.5)
    t = {w: 0.0 for w in range(4)}
    for step in range(1, 6):
        for w in range(4):
            dt = 1.0 if w != 3 else 2.5  # worker 3 is slow
            t[w] += dt
            mon.beat(w, step, now=t[w])
    assert mon.stragglers() == {3}


def test_preemption_guard_checkpoints_and_stops(tmp_path):
    cfg, state0, ds, step = _tiny_setup()
    mgr = CKPT.CheckpointManager(str(tmp_path), every=100, keep=2)

    with FT.PreemptionGuard(signals=(signal.SIGUSR1,)) as guard:
        def step_fn(state, batch):
            new_state, m = step(state, batch)
            if int(new_state.step) == 4:  # preempt mid-run
                os.kill(os.getpid(), signal.SIGUSR1)
            return new_state, m

        final, log = FT.run_training(state0, step_fn, ds.batch, 20,
                                     manager=mgr, guard=guard)
    assert guard.preempted
    assert int(final.step) < 20
    assert CKPT.latest_step(str(tmp_path)) == int(final.step)


@given(st.integers(16, 4096))
def test_replan_mesh_properties(n_chips):
    shape, axes = FT.replan_mesh(n_chips, model=16, pod_size=256)
    total = int(np.prod(shape))
    assert total <= n_chips                     # never oversubscribe
    assert shape[-1] in (16, 8, 4, 2, 1)        # TP axis preserved or halved
    assert total >= n_chips // 4                # uses most surviving chips
    assert len(shape) == len(axes)


def test_replan_keeps_tp_axis_when_possible():
    shape, axes = FT.replan_mesh(512, model=16, pod_size=256)
    assert shape == (2, 16, 16) and axes == ("pod", "data", "model")
    shape, axes = FT.replan_mesh(496, model=16, pod_size=256)
    # one pod lost 16 chips: 1 pod of (15, 16) + remainder ignored
    assert shape[-1] == 16


def test_shard_assignment_deterministic_and_balanced():
    a1 = FT.shard_assignment(64, [0, 1, 2, 5])
    a2 = FT.shard_assignment(64, [5, 2, 1, 0])
    assert a1 == a2
    sizes = [len(v) for v in a1.values()]
    assert max(sizes) - min(sizes) <= 1
    assert sorted(s for v in a1.values() for s in v) == list(range(64))
