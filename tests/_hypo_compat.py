"""Offline stand-in for `hypothesis` (property tests must collect/run
without network or optional deps).

``install()`` is a no-op when the real `hypothesis` package is importable
— real hypothesis is always preferred. Otherwise it registers a miniature,
API-compatible module as ``sys.modules['hypothesis']`` so test modules'
``from hypothesis import given, settings, strategies as st`` keep working
unchanged. The stand-in draws examples from a per-test fixed-seed RNG
(deterministic across runs, seeded from the test's qualified name), runs
``max_examples`` cases per test (boundary values first for integer
strategies — a crude, shrink-less nod to hypothesis's edge-case bias), and
supports the subset of the API this suite uses:

  given, settings (decorator + register_profile/load_profile), HealthCheck,
  st.integers, st.floats, st.lists, st.sampled_from, st.data.

It is NOT hypothesis: no shrinking, no database, no stateful testing. It
exists so the tier-1 suite keeps its property coverage offline instead of
erroring at collection (the offline-test compat policy, see ROADMAP.md).
"""

from __future__ import annotations

import functools
import inspect
import random
import sys
import types
import zlib

DEFAULT_MAX_EXAMPLES = 50


class _Strategy:
    """A draw function plus optional boundary examples (tried first)."""

    def __init__(self, draw, boundaries=()):
        self._draw = draw
        self.boundaries = tuple(boundaries)

    def example(self, rng, idx):
        if idx < len(self.boundaries):
            return self.boundaries[idx]
        return self._draw(rng)


def _integers(min_value=None, max_value=None):
    lo = -(2 ** 63) if min_value is None else int(min_value)
    hi = 2 ** 63 if max_value is None else int(max_value)
    return _Strategy(lambda rng: rng.randint(lo, hi), boundaries=(lo, hi))


def _floats(min_value=None, max_value=None, allow_nan=True,
            allow_infinity=None, width=64, **_kw):
    lo = -1e308 if min_value is None else float(min_value)
    hi = 1e308 if max_value is None else float(max_value)

    def draw(rng):
        v = rng.uniform(lo, hi)
        if width == 32:
            import numpy as np

            v = float(np.float32(v))
            # f32 rounding may step outside a tight [lo, hi]; clamp back
            v = min(max(v, lo), hi)
        return v

    return _Strategy(draw, boundaries=(lo, hi))


def _sampled_from(elements):
    elements = list(elements)
    # first/last as crude boundaries, mirroring the integer strategy
    bnd = (elements[0], elements[-1]) if elements else ()
    return _Strategy(lambda rng: rng.choice(elements), boundaries=bnd)


def _lists(elements, min_size=0, max_size=None, **_kw):
    cap = min_size + 10 if max_size is None else max_size

    def draw(rng):
        size = rng.randint(min_size, cap)
        return [elements.example(rng, 2 + i) for i in range(size)]

    return _Strategy(draw)


class _DataObject:
    """Interactive draws inside the test body (st.data())."""

    def __init__(self, rng):
        self._rng = rng

    def draw(self, strategy, label=None):
        return strategy.example(self._rng, 2)


def _data():
    return _Strategy(lambda rng: _DataObject(rng))


class _settings:
    """Decorator + profile registry, matching the hypothesis surface."""

    _profiles: dict = {}

    def __init__(self, max_examples=None, deadline=None,
                 suppress_health_check=None, **_kw):
        self.max_examples = max_examples

    def __call__(self, fn):
        if self.max_examples is not None:
            fn._hypo_max_examples = self.max_examples
        return fn

    @classmethod
    def register_profile(cls, name, *args, **kwargs):
        cls._profiles[name] = kwargs

    @classmethod
    def load_profile(cls, name):
        pass


class _HealthCheck:
    too_slow = "too_slow"
    data_too_large = "data_too_large"
    filter_too_much = "filter_too_much"


def _given(*strategies, **kw_strategies):
    def decorate(fn):
        # Positional strategies fill the RIGHTMOST parameters (hypothesis
        # semantics); earlier ones (self, fixtures) stay visible to pytest.
        # Bind drawn values by NAME so fixtures passed as kwargs can never
        # collide with them.
        sig = inspect.signature(fn)
        names = list(sig.parameters)
        strat_names = names[len(names) - len(strategies):] if strategies \
            else []

        @functools.wraps(fn)
        def wrapper(*args, **kwargs):
            n = (getattr(wrapper, "_hypo_max_examples", None)
                 or getattr(fn, "_hypo_max_examples", None)
                 or DEFAULT_MAX_EXAMPLES)
            rng = random.Random(zlib.crc32(fn.__qualname__.encode()))
            for idx in range(n):
                kwvals = {nm: s.example(rng, idx)
                          for nm, s in zip(strat_names, strategies)}
                kwvals.update((k, s.example(rng, idx))
                              for k, s in kw_strategies.items())
                fn(*args, **kwargs, **kwvals)

        # Hide the strategy-filled parameters from pytest (like hypothesis
        # does), or it would try to resolve them as fixtures.
        hidden = set(strat_names) | set(kw_strategies)
        params = [p for p in sig.parameters.values()
                  if p.name not in hidden]
        wrapper.__signature__ = sig.replace(parameters=params)
        del wrapper.__wrapped__  # keep inspect from following back to fn
        return wrapper
    return decorate


def install() -> bool:
    """Register the stand-in if real hypothesis is absent. Returns True
    when the real package is in use (idempotent: recognizes a previously
    installed shim and keeps reporting False for it)."""
    try:
        import hypothesis

        return not getattr(hypothesis, "__is_repro_offline_shim__", False)
    except ImportError:
        pass

    st = types.ModuleType("hypothesis.strategies")
    st.integers = _integers
    st.floats = _floats
    st.lists = _lists
    st.sampled_from = _sampled_from
    st.data = _data

    mod = types.ModuleType("hypothesis")
    mod.given = _given
    mod.settings = _settings
    mod.HealthCheck = _HealthCheck
    mod.strategies = st
    mod.__is_repro_offline_shim__ = True

    sys.modules["hypothesis"] = mod
    sys.modules["hypothesis.strategies"] = st
    return False


install()
