"""End-to-end system tests: the full public path (configs -> data -> train
-> checkpoint -> serve) plus a real single-cell dry-run in a subprocess."""

import json
import os
import subprocess
import sys

import jax
import jax.numpy as jnp
import pytest

from repro.configs import registry as REG
from repro.configs.base import ShapeConfig
from repro.models import model as MD
from repro.serve import decode as D
from repro.train import data as DATA
from repro.train import optimizer as OPT
from repro.train import train_step as TS


def test_train_then_generate_end_to_end():
    cfg = REG.smoke_config("mixtral-8x7b")  # MoE + SWA path
    opt = OPT.OptConfig(lr=3e-3, warmup_steps=2, total_steps=15)
    state = TS.init_state(jax.random.key(0), cfg, opt)
    shape = ShapeConfig("t", 64, 4, "train")
    ds = DATA.SyntheticLM(cfg, shape, act_dtype=jnp.float32)
    step = jax.jit(TS.make_train_step(cfg, opt), donate_argnums=(0,))
    first = last = None
    for i in range(15):
        state, m = step(state, ds.batch(i))
        first = first if first is not None else float(m["loss"])
        last = float(m["loss"])
    assert last < first

    cache = MD.init_cache(cfg, 2, 32, jnp.float32)
    toks, _, _ = D.generate(state.params, cfg, cache,
                            jnp.array([[1], [2]], jnp.int32),
                            jnp.zeros((2,), jnp.int32), 8)
    assert toks.shape == (2, 8)
    assert bool(jnp.all((toks >= 0) & (toks < cfg.vocab_size)))


def test_train_driver_cli(tmp_path):
    """The launch/train.py driver end-to-end, including restart."""
    from repro.launch import train as TR
    state, log = TR.main(["--arch", "internvl2-1b", "--steps", "6",
                          "--batch", "2", "--seq", "48",
                          "--ckpt-dir", str(tmp_path), "--ckpt-every", "3"])
    assert int(state.step) == 6
    # restart picks up from the checkpoint and continues
    state2, log2 = TR.main(["--arch", "internvl2-1b", "--steps", "8",
                            "--batch", "2", "--seq", "48",
                            "--ckpt-dir", str(tmp_path),
                            "--ckpt-every", "3"])
    assert int(state2.step) == 8
    assert len(log2) == 2  # only steps 7..8 re-run


def test_dryrun_single_cell_subprocess():
    """One real dry-run cell on the 256-chip mesh (the full sweep runs via
    `python -m repro.launch.dryrun --all`; this guards the machinery)."""
    env = dict(os.environ, PYTHONPATH="src")
    r = subprocess.run(
        [sys.executable, "-m", "repro.launch.dryrun", "--arch",
         "granite-moe-3b-a800m", "--shape", "decode_32k", "--mesh",
         "single", "--out", "/tmp/dryrun_test", "--force"],
        capture_output=True, text=True, timeout=540, cwd="/root/repo",
        env=env)
    assert " ok " in r.stdout, r.stdout + r.stderr[-2000:]
    rec = json.load(open(
        "/tmp/dryrun_test/granite-moe-3b-a800m__decode_32k__single.json"))
    assert rec["ok"]
    assert rec["n_chips"] == 256
    assert rec["roofline"]["dominant"] in ("compute", "memory", "collective")
    assert rec["analysis"]["memory"]["peak_bytes_per_device"] < 16e9
