#!/usr/bin/env bash
# Tier-1 verification, exactly as the roadmap specifies, plus the offline
# guard: the suite must collect and pass with no network and no optional
# deps (hypothesis is shimmed by tests/_hypo_compat.py when absent).
#
#   scripts/check.sh            # tier-1 + no-network guard + bench smoke
#   scripts/check.sh -k tet     # extra args forwarded to pytest
#                               # (bench smoke skipped when args are given)
set -euo pipefail
cd "$(dirname "$0")/.."

export PYTHONPATH="src:scripts${PYTHONPATH:+:$PYTHONPATH}"

# Static contract lint tier: envelope certification, schedule contract
# proofs, jaxpr/HLO structural rules (see src/repro/analysis/README.md).
# Gating and fully offline — nothing executes a kernel. Skipped when
# pytest args are forwarded so `scripts/check.sh -k foo` stays fast.
if [ "$#" -eq 0 ]; then
    echo "== contract lint =="
    python -m repro.analysis.lint -q --json artifacts/lint_report.json
fi

# -p _offline_guard turns any outbound connection attempt into a failure,
# so offline-collectability cannot regress silently.
python -m pytest -x -q -p _offline_guard "$@"

# Benchmark smoke tier: every benchmark script must still EXECUTE offline
# (tiny n, scan impls) so the scripts cannot silently rot between the
# occasions someone runs them at full scale.
if [ "$#" -eq 0 ]; then
    echo "== benchmarks --smoke =="
    python -m benchmarks.run --smoke
    # The packed mixed-position decode path also has its own CLI entry;
    # exercise it directly so the --decode argparse surface cannot rot
    # (benchmarks.run --smoke already covers the underlying run_decode).
    echo "== bench_packed --decode --smoke =="
    python -m benchmarks.bench_packed --decode --smoke
    # Same for the packed ragged-document training step (fwd + bwd through
    # the custom VJP): the --train surface and its packed < padded tile
    # assertion must keep executing offline.
    echo "== bench_packed --train --smoke =="
    python -m benchmarks.bench_packed --train --smoke
    # And the fused continuous-batching step: its own CLI surface plus the
    # 1-launch / fused == split tile assertions must keep executing
    # offline (benchmarks.run --smoke covers the underlying run()).
    echo "== bench_continuous --smoke =="
    python -m benchmarks.bench_continuous --smoke
    # Telemetry smoke tier: the benchmarks.run --smoke above wrote
    # artifacts/metrics.json, a trace JSONL, and appended a record to
    # BENCH_trajectory.json — all three must be schema-valid
    # (src/repro/obs/schema.py), so the metric/trace formats cannot
    # drift from their validators.
    echo "== obs validate (metrics.json / trajectory / trace) =="
    python -m repro.obs.validate artifacts/metrics.json \
        BENCH_trajectory.json artifacts/trace/*.jsonl
    # Fault-injection smoke tier: the serving engine under seeded
    # FaultPlans (launch errors, OOM admissions, poisoned tiles,
    # stragglers) must stay token-identical to fault-free, with every
    # degrade/quarantine schema-valid in the trace — run under the same
    # no-network guard as the test suite (PYTHONPATH includes scripts).
    echo "== resilience smoke (fault injection, offline) =="
    python -m repro.resilience.smoke
    # Fleet failover smoke tier: two replicas behind tile-cost routing
    # under an engine-killing plan, both step modes — migrated requests
    # must finish token-identically to a fault-free single engine, with
    # every failover/quarantine/rebalance event schema-valid.
    echo "== fleet resilience smoke (failover, offline) =="
    python -m repro.resilience.smoke --fleet
fi
