#!/usr/bin/env bash
# Tier-1 verification, exactly as the roadmap specifies, plus the offline
# guard: the suite must collect and pass with no network and no optional
# deps (hypothesis is shimmed by tests/_hypo_compat.py when absent).
#
#   scripts/check.sh            # tier-1 + no-network guard
#   scripts/check.sh -k tet     # extra args forwarded to pytest
set -euo pipefail
cd "$(dirname "$0")/.."

export PYTHONPATH="src:scripts${PYTHONPATH:+:$PYTHONPATH}"

# -p _offline_guard turns any outbound connection attempt into a failure,
# so offline-collectability cannot regress silently.
python -m pytest -x -q -p _offline_guard "$@"
