"""pytest plugin: forbid outbound network connections during the suite.

Loaded by scripts/check.sh via ``-p _offline_guard``. The tier-1 suite
must collect and pass fully offline (the offline-test compat policy —
see tests/conftest.py); this guard turns any accidental network
dependency (package download, dataset fetch, telemetry) into a hard,
attributable failure instead of a hang or a silently-skipped test.

Loopback and AF_UNIX are allowed: local subprocess plumbing is not
network access.
"""

from __future__ import annotations

import socket

_LOCAL_HOSTS = {"localhost", "127.0.0.1", "::1", ""}
_real_connect = socket.socket.connect
_real_connect_ex = socket.socket.connect_ex
_real_sendto = socket.socket.sendto
_real_getaddrinfo = socket.getaddrinfo


class OfflineViolation(OSError):
    pass


def _is_local(address) -> bool:
    host = address[0] if isinstance(address, tuple) and address else address
    if isinstance(host, (bytes, bytearray)):
        host = host.decode("utf-8", "replace")
    return host is None or (isinstance(host, str)
                            and host.split("%")[0] in _LOCAL_HOSTS)


def _violation(what, address):
    raise OfflineViolation(
        f"offline CI guard: outbound {what} to {address!r} is forbidden "
        f"(the suite must run without network; see scripts/check.sh)")


def _guarded_connect(self, address):
    if self.family == getattr(socket, "AF_UNIX", object()) \
            or _is_local(address):
        return _real_connect(self, address)
    _violation("connection", address)


def _guarded_connect_ex(self, address):
    if self.family == getattr(socket, "AF_UNIX", object()) \
            or _is_local(address):
        return _real_connect_ex(self, address)
    _violation("connection (connect_ex)", address)


def _guarded_sendto(self, *args):
    # sendto(data, address) or sendto(data, flags, address)
    address = args[-1] if args else None
    if self.family == getattr(socket, "AF_UNIX", object()) \
            or _is_local(address):
        return _real_sendto(self, *args)
    _violation("datagram (sendto)", address)


def _guarded_getaddrinfo(host, *args, **kwargs):
    if _is_local(host):
        return _real_getaddrinfo(host, *args, **kwargs)
    _violation("name resolution (getaddrinfo)", host)


def install():
    """Apply the guard (idempotent). Usable outside pytest too — the
    resilience smoke tier calls this directly so its engine runs are
    provably offline."""
    socket.socket.connect = _guarded_connect
    socket.socket.connect_ex = _guarded_connect_ex
    socket.socket.sendto = _guarded_sendto
    socket.getaddrinfo = _guarded_getaddrinfo


def uninstall():
    socket.socket.connect = _real_connect
    socket.socket.connect_ex = _real_connect_ex
    socket.socket.sendto = _real_sendto
    socket.getaddrinfo = _real_getaddrinfo


def pytest_configure(config):
    install()


def pytest_unconfigure(config):
    uninstall()
